//! Integration tests for the telemetry layer: Chrome-trace export shape,
//! per-thread span nesting, and the simulated-timeline conservation
//! contract — the per-kind busy/energy totals folded from `--trace-sim`
//! events must equal [`SimReport::kinds`] **bitwise**, because the
//! exporter emits one event per `KindTotals` addition in the evaluator's
//! own walk order (see `plan::sim_timeline_soa`).
//!
//! [`SimReport::kinds`]: ghost::coordinator::SimReport

use std::collections::BTreeMap;

use ghost::config::GhostConfig;
use ghost::coordinator::{sim_timeline, sim_timeline_sharded, BatchEngine, OptFlags, SimRequest};
use ghost::gnn::models::ModelKind;
use ghost::util::json::Json;
use ghost::util::telemetry;

const TABLE2: [&str; 8] =
    ["Cora", "PubMed", "Citeseer", "Amazon", "Proteins", "Mutag", "BZR", "IMDB-binary"];

/// Folds every `cat:"sim-stage"` event's exact `args` addends per kind, in
/// array (= walk) order — the same f64 addition sequence the evaluator
/// performs, so the result must be bit-identical to the report totals.
fn fold_sim_stage(doc: &Json) -> BTreeMap<String, (f64, f64)> {
    let events = doc.get("traceEvents").and_then(Json::as_array).expect("traceEvents array");
    let mut sums: BTreeMap<String, (f64, f64)> = BTreeMap::new();
    for e in events {
        if e.get("cat").and_then(Json::as_str) != Some("sim-stage") {
            continue;
        }
        let name = e.get("name").and_then(Json::as_str).expect("stage name").to_string();
        let args = e.get("args").expect("sim-stage args");
        let busy = args.get("busy_s").and_then(Json::as_f64).expect("busy_s");
        let energy = args.get("energy_j").and_then(Json::as_f64).expect("energy_j");
        let entry = sums.entry(name).or_insert((0.0, 0.0));
        entry.0 += busy;
        entry.1 += energy;
    }
    sums
}

/// Asserts the conservation contract for one rendered timeline against the
/// report it was derived from.
fn assert_conserved(doc: &Json, report: &ghost::coordinator::SimReport, label: &str) {
    // Round-trip through the serialized text: the CI checker reads the
    // file, so exactness must survive Display + parse.
    let text = format!("{doc}");
    let parsed = Json::parse(&text).expect("timeline must parse back");
    let sums = fold_sim_stage(&parsed);
    let ghost_totals = parsed.get("ghost").and_then(|g| g.get("kind_totals")).expect("kind_totals");
    for (name, cost) in report.kinds.rows() {
        let (busy, energy) = sums.get(name).copied().unwrap_or((0.0, 0.0));
        assert_eq!(
            busy.to_bits(),
            cost.latency_s.to_bits(),
            "{label}: {name} busy_s drifted: folded {busy:e}, report {:e}",
            cost.latency_s
        );
        assert_eq!(
            energy.to_bits(),
            cost.energy_j.to_bits(),
            "{label}: {name} energy_j drifted: folded {energy:e}, report {:e}",
            cost.energy_j
        );
        let embedded = ghost_totals.get(name).expect("every kind present in ghost.kind_totals");
        assert_eq!(
            embedded.get("busy_s").and_then(Json::as_f64).map(f64::to_bits),
            Some(cost.latency_s.to_bits()),
            "{label}: embedded {name} busy_s != report"
        );
        assert_eq!(
            embedded.get("energy_j").and_then(Json::as_f64).map(f64::to_bits),
            Some(cost.energy_j.to_bits()),
            "{label}: embedded {name} energy_j != report"
        );
    }
}

#[test]
fn sim_timeline_conserves_kind_totals_exactly() {
    let engine = BatchEngine::new();
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    for dataset in TABLE2 {
        for model in [ModelKind::Gcn, ModelKind::Gat] {
            let req = SimRequest::new(model, dataset, cfg, flags);
            for shards in [1usize, 4] {
                let label = format!("{model:?}/{dataset}/shards={shards}");
                let (doc, report) = if shards == 1 {
                    let plan = engine.plan(&req).expect("plan");
                    (sim_timeline(&plan).expect("timeline"), engine.run(&req).expect("run"))
                } else {
                    let plan = engine.sharded_plan(&req, shards).expect("sharded plan");
                    (
                        sim_timeline_sharded(&plan).expect("timeline"),
                        engine.run_sharded(&req, shards).expect("run"),
                    )
                };
                assert_conserved(&doc, &report, &label);
            }
        }
    }
}

#[test]
fn sim_timeline_renders_tracks_and_barriers() {
    let engine = BatchEngine::new();
    let cfg = GhostConfig::paper_optimal();
    let req = SimRequest::new(ModelKind::Gcn, "PubMed", cfg, OptFlags::ghost_default());
    let plan = engine.sharded_plan(&req, 4).expect("sharded plan");
    let doc = sim_timeline_sharded(&plan).expect("timeline");
    let text = format!("{doc}");
    let parsed = Json::parse(&text).expect("parses");
    let meta = parsed.get("ghost").expect("ghost metadata");
    assert_eq!(meta.get("chips").and_then(Json::as_u64), Some(4), "4 chips expected");
    let events = parsed.get("traceEvents").and_then(Json::as_array).unwrap();
    // Track metadata: every chip is a named viewer process with a serial
    // track and four pipeline-position tracks.
    let process_names = events
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("process_name"))
        .count();
    assert_eq!(process_names, 4, "one process_name per chip");
    let thread_names = events
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("thread_name"))
        .count();
    assert_eq!(thread_names, 4 * 5, "serial + 4 pipe tracks per chip");
    // Cross-chip communication renders as remote_gather stages, and phase
    // hand-offs as barrier instants.
    assert!(
        events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("remote_gather")
                && e.get("cat").and_then(Json::as_str) == Some("sim-stage")
        }),
        "sharded timeline must show remote_gather stages"
    );
    let phases = meta.get("phases").and_then(Json::as_u64).expect("phase count");
    let barriers = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("barrier"))
        .count() as u64;
    assert_eq!(barriers, 4 * (phases - 1), "one barrier instant per chip per hand-off");
    // Timestamps are modeled time: every sim-stage box ends within the
    // modeled makespan (+ slack for f64 µs conversion).
    let latency_s = meta.get("latency_s").and_then(Json::as_f64).expect("latency_s");
    let latency_us = latency_s * 1e6;
    for e in events.iter().filter(|e| e.get("cat").and_then(Json::as_str) == Some("sim-stage")) {
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        let dur = e.get("dur").and_then(Json::as_f64).unwrap();
        assert!(
            ts + dur <= latency_us * (1.0 + 1e-9),
            "stage [{ts}, {}] exceeds makespan {latency_us}",
            ts + dur
        );
    }
}

#[test]
fn wall_trace_spans_nest_per_thread() {
    telemetry::set_enabled(true);
    let engine = BatchEngine::new();
    let req = SimRequest::new(
        ModelKind::Gcn,
        "Cora",
        GhostConfig::paper_optimal(),
        OptFlags::ghost_default(),
    );
    engine.run(&req).expect("run");
    engine.run_sharded(&req, 2).expect("sharded run");
    let doc = telemetry::trace::wall_trace_json();
    let text = format!("{doc}");
    let parsed = Json::parse(&text).expect("wall trace parses");
    let events = parsed.get("traceEvents").and_then(Json::as_array).expect("traceEvents");

    let mut spans: Vec<(u64, f64, f64)> = Vec::new(); // (tid, ts, dur)
    let mut names: Vec<&str> = Vec::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        names.push(e.get("name").and_then(Json::as_str).unwrap_or(""));
        spans.push((
            e.get("tid").and_then(Json::as_u64).expect("tid"),
            e.get("ts").and_then(Json::as_f64).expect("ts"),
            e.get("dur").and_then(Json::as_f64).expect("dur"),
        ));
    }
    for expect in ["plan.build", "plan.evaluate", "plan.evaluate_sharded", "partition.build_all"] {
        assert!(names.contains(&expect), "wall trace missing span {expect}: {names:?}");
    }

    // Per-tid containment: sorted by (start asc, dur desc), a stack walk
    // must never see a span that straddles its enclosing span's end.
    spans.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)).then(b.2.total_cmp(&a.2)));
    let slack = 1e-3; // µs; conversion rounding is ~1e-10 µs
    let mut stack: Vec<(u64, f64, f64)> = Vec::new();
    for &(tid, ts, dur) in &spans {
        while let Some(&(top_tid, top_ts, top_dur)) = stack.last() {
            if top_tid != tid || ts >= top_ts + top_dur - slack {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(&(_, top_ts, top_dur)) = stack.last() {
            assert!(
                ts + dur <= top_ts + top_dur + slack,
                "span on tid {tid} [{ts}, {}] straddles parent [{top_ts}, {}]",
                ts + dur,
                top_ts + top_dur
            );
        }
        stack.push((tid, ts, dur));
    }

    // The registry snapshot rides along for checkers.
    assert!(
        parsed
            .get("ghost")
            .and_then(|g| g.get("metrics"))
            .and_then(|m| m.get("counters"))
            .is_some(),
        "wall trace must embed the metric snapshot"
    );
}

#[test]
fn registry_counters_mirror_engine_getters() {
    // The adopted global-engine counters and the ad-hoc getters are the
    // same atomics — not two counts that could drift.
    let engine = BatchEngine::global();
    let req = SimRequest::new(
        ModelKind::Gcn,
        "Cora",
        GhostConfig::paper_optimal(),
        OptFlags::ghost_default(),
    );
    engine.run(&req).expect("run");
    engine.run(&req).expect("run again (cache hit)");
    let snap = telemetry::registry().snapshot();
    let counters = snap.get("counters").expect("counters");
    let registered = counters
        .get("engine.plan.builds")
        .and_then(Json::as_u64)
        .expect("engine.plan.builds registered") as usize;
    assert_eq!(registered, engine.plan_builds(), "registry and getter must agree");
    assert!(registered >= 1);
}
