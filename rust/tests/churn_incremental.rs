//! Property and integration tests for the incremental graph-churn engine:
//! CSR splicing vs edge-list rebuilds, partition splicing vs the serial
//! reference builder, and `GraphDeltaPlan` patches vs cold plan rebuilds
//! across models and shard counts. Every comparison here is exact
//! (`assert_eq!`) — the incremental paths promise bit-identity, not
//! approximation.

use ghost::config::GhostConfig;
use ghost::coordinator::{plan, GraphDeltaPlan, OptFlags};
use ghost::gnn::models::ModelKind;
use ghost::graph::csr::CsrGraph;
use ghost::graph::datasets::Dataset;
use ghost::graph::mutate::{
    apply_batch, apply_to_dataset, random_batch, GraphDelta, MutateError,
};
use ghost::graph::partition::PartitionMatrix;
use ghost::util::rng::{mix_seed, Pcg64};

/// Replays a delta batch against a plain edge list — the O(V + E)
/// reference the CSR splicer must agree with.
fn replay_on_edge_list(
    graph: &CsrGraph,
    batch: &[GraphDelta],
) -> (usize, Vec<(u32, u32)>) {
    let mut n_vertices = graph.n_vertices;
    let mut edges: Vec<(u32, u32)> =
        (0..graph.n_edges()).map(|e| graph.edge_endpoints(e)).collect();
    for &op in batch {
        match op {
            GraphDelta::AddVertex => n_vertices += 1,
            GraphDelta::AddEdge { src, dst } => edges.push((src, dst)),
            GraphDelta::RemoveEdge { src, dst } => {
                let at = edges
                    .iter()
                    .position(|&e| e == (src, dst))
                    .expect("validated removal exists in the mirror");
                edges.swap_remove(at);
            }
        }
    }
    (n_vertices, edges)
}

#[test]
fn random_batches_splice_csr_identical_to_edge_list_rebuild() {
    let base = Dataset::by_name("rmat-800v-5000e-8f-4l").unwrap();
    for seed in 0..12u64 {
        let mut rng = Pcg64::seed_from_u64(mix_seed(seed, 0));
        // Chain three batches so later batches run against spliced output,
        // not just the pristine generator graph.
        let mut graph = base.graphs[0].clone();
        for round in 0..3 {
            let batch = random_batch(&graph, 120, 0.5, 0.15, &mut rng);
            let (n_vertices, edges) = replay_on_edge_list(&graph, &batch);
            let patch = apply_batch(&graph, &batch)
                .expect("random batches always validate");
            assert_eq!(
                patch.graph,
                CsrGraph::from_edges(n_vertices, &edges),
                "seed {seed} round {round}: spliced CSR diverged from a \
                 from_edges rebuild of the mutated edge multiset"
            );
            assert_eq!(
                patch.graph.n_edges(),
                graph.n_edges() + patch.edges_added - patch.edges_removed,
                "seed {seed} round {round}: edge conservation"
            );
            // Touched rows must cover every row whose content changed.
            for dst in 0..graph.n_vertices {
                if graph.neighbors(dst) != patch.graph.neighbors(dst)
                    && !patch.touched_dsts.contains(&(dst as u32))
                {
                    panic!("seed {seed} round {round}: row {dst} changed silently");
                }
            }
            graph = patch.graph;
        }
    }
}

#[test]
fn spliced_partitions_match_serial_rebuild_across_block_shapes() {
    for (v, n) in [(8usize, 8usize), (20, 20), (13, 7)] {
        let mut dataset = Dataset::by_name("rmat-1500v-9000e-8f-4l").unwrap();
        let mut partitions =
            PartitionMatrix::build_all(&dataset.graphs, v, n);
        let mut rng = Pcg64::seed_from_u64(mix_seed(7, v as u64));
        for round in 0..4 {
            let batch =
                random_batch(&dataset.graphs[0], 90, 0.5, 0.2, &mut rng);
            apply_to_dataset(&mut dataset, &mut partitions, 0, &batch)
                .expect("random batches always validate");
            assert_eq!(
                partitions[0],
                PartitionMatrix::build_serial(&dataset.graphs[0], v, n),
                "({v},{n}) round {round}: spliced partition diverged from \
                 the serial reference builder"
            );
        }
        assert_eq!(dataset.epoch, 4, "({v},{n}): one epoch bump per batch");
    }
}

#[test]
fn patched_plans_match_cold_rebuilds_across_models_and_shards() {
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    for (kind, name) in [(ModelKind::Gcn, "Cora"), (ModelKind::Gat, "Citeseer")] {
        for shards in [1usize, 4] {
            let mut dataset = Dataset::by_name(name).unwrap();
            let mut partitions =
                PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
            let mut dp = GraphDeltaPlan::new(kind, &dataset.spec, cfg, flags, shards);
            dp.retarget_graph(&dataset, &partitions, None).expect("priming rebuild");
            let mut rng = Pcg64::seed_from_u64(mix_seed(11, shards as u64));
            const EPOCHS: usize = 3;
            for epoch in 0..EPOCHS {
                // Pure edge churn: the group count stays fixed, so the
                // single-chip plan must take the patch path every epoch.
                let batch =
                    random_batch(&dataset.graphs[0], 64, 0.6, 0.0, &mut rng);
                let applied =
                    apply_to_dataset(&mut dataset, &mut partitions, 0, &batch)
                        .expect("random batches always validate");
                dp.retarget_graph(
                    &dataset,
                    &partitions,
                    Some(std::slice::from_ref(&applied)),
                )
                .expect("retarget after mutation");
                let incremental = dp.evaluate().expect("patched evaluation");
                let cold_partitions =
                    PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
                let cold = if shards == 1 {
                    let p = plan::build(kind, &dataset, &cold_partitions, cfg, flags)
                        .expect("cold build");
                    plan::evaluate(&p).expect("cold evaluation")
                } else {
                    let p = plan::build_sharded(
                        kind, &dataset, &cold_partitions, cfg, flags, shards,
                    )
                    .expect("cold sharded build");
                    plan::evaluate_sharded(&p).expect("cold sharded evaluation")
                };
                assert_eq!(
                    incremental, cold,
                    "{kind:?}/{name} shards={shards} epoch {epoch}: patched \
                     plan diverged from a cold rebuild"
                );
            }
            if shards == 1 {
                assert_eq!(dp.rebuilds(), 1, "{kind:?}/{name}: priming only");
                assert_eq!(dp.patches(), EPOCHS, "{kind:?}/{name}: pure patches");
            } else {
                // Sharded plans fall back to rebuilds; the counters prove
                // the fallback is taken rather than silently mis-patching.
                assert_eq!(dp.rebuilds(), 1 + EPOCHS, "{kind:?}/{name} sharded");
                assert_eq!(dp.patches(), 0, "{kind:?}/{name} sharded");
            }
        }
    }
}

#[test]
fn vertex_growth_across_a_group_boundary_forces_a_rebuild() {
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    let mut dataset = Dataset::by_name("Cora").unwrap();
    let mut partitions = PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
    let mut dp = GraphDeltaPlan::new(ModelKind::Gcn, &dataset.spec, cfg, flags, 1);
    dp.retarget_graph(&dataset, &partitions, None).expect("priming rebuild");
    // Enough vertices to guarantee the output-group count grows (v = 20).
    let batch = vec![GraphDelta::AddVertex; cfg.v + 1];
    let applied = apply_to_dataset(&mut dataset, &mut partitions, 0, &batch)
        .expect("vertex growth always validates");
    assert!(applied.new_n_groups > applied.old_n_groups);
    dp.retarget_graph(&dataset, &partitions, Some(std::slice::from_ref(&applied)))
        .expect("retarget after growth");
    assert_eq!(dp.rebuilds(), 2, "group-count change must rebuild, not patch");
    assert_eq!(dp.patches(), 0);
    let incremental = dp.evaluate().expect("evaluation after growth");
    let cold_partitions = PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
    let p = plan::build(ModelKind::Gcn, &dataset, &cold_partitions, cfg, flags)
        .expect("cold build");
    assert_eq!(incremental, plan::evaluate(&p).expect("cold evaluation"));
}

#[test]
fn multi_graph_dataset_patches_only_the_mutated_graph() {
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    let mut dataset = Dataset::by_name("Mutag").unwrap();
    assert!(dataset.graphs.len() > 1, "Mutag is the multi-graph case");
    let mut partitions = PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
    let mut dp = GraphDeltaPlan::new(ModelKind::Gin, &dataset.spec, cfg, flags, 1);
    dp.retarget_graph(&dataset, &partitions, None).expect("priming rebuild");
    let mut rng = Pcg64::seed_from_u64(mix_seed(23, 0));
    for (round, graph) in [7usize, 0, 150].into_iter().enumerate() {
        let batch = random_batch(&dataset.graphs[graph], 10, 0.7, 0.0, &mut rng);
        let applied = apply_to_dataset(&mut dataset, &mut partitions, graph, &batch)
            .expect("random batches always validate");
        assert_eq!(applied.graph, graph);
        dp.retarget_graph(&dataset, &partitions, Some(std::slice::from_ref(&applied)))
            .expect("retarget after mutation");
        let incremental = dp.evaluate().expect("patched evaluation");
        let cold_partitions =
            PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
        let p = plan::build(ModelKind::Gin, &dataset, &cold_partitions, cfg, flags)
            .expect("cold build");
        assert_eq!(
            incremental,
            plan::evaluate(&p).expect("cold evaluation"),
            "round {round} (graph {graph}): patched multi-graph plan diverged"
        );
    }
    assert_eq!(dp.rebuilds(), 1);
    assert_eq!(dp.patches(), 3);
}

#[test]
fn rejected_batches_leave_dataset_partitions_and_epoch_untouched() {
    let mut dataset = Dataset::by_name("Cora").unwrap();
    let mut partitions = PartitionMatrix::build_all(&dataset.graphs, 20, 20);
    let graphs_before = dataset.graphs.clone();
    let partitions_before = partitions.clone();
    let n = dataset.graphs[0].n_vertices as u32;

    // A vertex added mid-batch has no edges, so removing one must fail —
    // after the earlier ops in the batch already passed validation.
    let missing = vec![
        GraphDelta::AddEdge { src: 0, dst: 1 },
        GraphDelta::AddVertex,
        GraphDelta::RemoveEdge { src: n, dst: n },
    ];
    match apply_to_dataset(&mut dataset, &mut partitions, 0, &missing) {
        Err(MutateError::MissingEdge { index: 2, src, dst }) => {
            assert_eq!((src, dst), (n, n));
        }
        other => panic!("expected MissingEdge, got {other:?}"),
    }

    let out_of_range = vec![GraphDelta::AddEdge { src: n, dst: 0 }];
    match apply_to_dataset(&mut dataset, &mut partitions, 0, &out_of_range) {
        Err(MutateError::VertexOutOfRange { index: 0, vertex, .. }) => {
            assert_eq!(vertex, n);
        }
        other => panic!("expected VertexOutOfRange, got {other:?}"),
    }

    assert!(matches!(
        apply_to_dataset(&mut dataset, &mut partitions, 99, &[]),
        Err(MutateError::GraphOutOfRange { graph: 99, n_graphs: 1 })
    ));

    assert_eq!(dataset.graphs, graphs_before, "rejected batches must not splice");
    assert_eq!(partitions, partitions_before);
    assert_eq!(dataset.epoch, 0, "rejected batches must not bump the epoch");
}
