//! Integration tests for the batched simulation engine and the structured
//! error paths: partition-cache reuse across configurations sharing a
//! `(dataset, V, N)` shape, release-mode rejection of mismatched
//! partitions, per-point failure reporting in the DSE sweep, and engine
//! results being bit-identical to the uncached serial simulator.

use std::sync::Arc;

use ghost::config::GhostConfig;
use ghost::coordinator::dse;
use ghost::coordinator::{
    simulate_with_partitions, simulate_workload, BatchEngine, OptFlags, SimError, SimRequest,
};
use ghost::gnn::models::ModelKind;
use ghost::graph::datasets::Dataset;
use ghost::graph::partition::PartitionMatrix;

#[test]
fn partition_sets_built_once_per_distinct_shape() {
    let engine = BatchEngine::new();
    let flags = OptFlags::ghost_default();
    let base = GhostConfig::paper_optimal();
    // Three configs share (V, N) = (20, 20) — they differ only in array
    // shapes, which partitioning never sees — plus one distinct shape.
    let cfgs = [
        base,
        GhostConfig { t_r: 11, ..base },
        GhostConfig { r_c: 14, ..base },
        GhostConfig { v: 10, n: 10, ..base },
    ];
    let reqs: Vec<SimRequest> = cfgs
        .iter()
        .map(|&cfg| SimRequest::new(ModelKind::Gcn, "Cora", cfg, flags))
        .collect();
    for r in engine.run_batch(&reqs) {
        r.expect("every request simulates");
    }
    assert_eq!(engine.dataset_builds(), 1, "Cora generated once");
    assert_eq!(engine.partition_builds(), 2, "one build per distinct (dataset, V, N)");
    // Re-running the whole batch hits the caches only.
    for r in engine.run_batch(&reqs) {
        r.expect("every request simulates");
    }
    assert_eq!(engine.partition_builds(), 2);
    assert_eq!(engine.dataset_builds(), 1);
}

#[test]
fn engine_results_identical_to_serial_simulation() {
    let engine = BatchEngine::new();
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    let pairs =
        [(ModelKind::Gcn, "Cora"), (ModelKind::Gat, "Citeseer"), (ModelKind::Gin, "Mutag")];
    let reqs: Vec<SimRequest> =
        pairs.iter().map(|&(kind, ds)| SimRequest::new(kind, ds, cfg, flags)).collect();
    let batch = engine.run_batch(&reqs);
    for (&(kind, name), via_engine) in pairs.iter().zip(batch) {
        let via_engine = via_engine.expect("engine run");
        let ds = Dataset::by_name(name).unwrap();
        let serial = simulate_workload(kind, &ds, cfg, flags).unwrap();
        assert_eq!(via_engine.metrics, serial.metrics, "{name}");
        assert_eq!(via_engine.aggregate_s, serial.aggregate_s, "{name}");
        assert_eq!(via_engine.combine_s, serial.combine_s, "{name}");
        assert_eq!(via_engine.update_s, serial.update_s, "{name}");
        assert_eq!(via_engine.platform_w, serial.platform_w, "{name}");
    }
}

#[test]
fn mismatched_partitions_rejected_even_in_release() {
    // These used to be debug_asserts, i.e. wrong metrics in --release.
    let ds = Dataset::by_name("Cora").unwrap();
    let cfg = GhostConfig::paper_optimal(); // (V, N) = (20, 20)
    let flags = OptFlags::ghost_default();

    let wrong_shape: Vec<PartitionMatrix> =
        ds.graphs.iter().map(|g| PartitionMatrix::build(g, 10, 10)).collect();
    let err = simulate_with_partitions(ModelKind::Gcn, &ds, &wrong_shape, cfg, flags)
        .expect_err("wrong (V, N) must be rejected");
    assert_eq!(
        err,
        SimError::PartitionShapeMismatch { expected: (20, 20), got: (10, 10) }
    );

    let err = simulate_with_partitions(ModelKind::Gcn, &ds, &[], cfg, flags)
        .expect_err("missing partitions must be rejected");
    assert_eq!(err, SimError::PartitionCountMismatch { expected: 1, got: 0 });
}

#[test]
fn unknown_dataset_degrades_to_error_value() {
    let engine = BatchEngine::new();
    let req = SimRequest::new(
        ModelKind::Gcn,
        "NoSuchDataset",
        GhostConfig::paper_optimal(),
        OptFlags::ghost_default(),
    );
    assert_eq!(
        engine.run(&req).unwrap_err(),
        SimError::UnknownDataset("NoSuchDataset".into())
    );
}

#[test]
fn parameterized_rmat_datasets_cached_like_table2_names() {
    // The large-graph tier must ride the same (dataset, V, N) cache as the
    // Table-2 names: different spellings of one rmat spec share one
    // canonical identity, and each distinct shape builds exactly once.
    let engine = BatchEngine::new();
    let a = engine.partitions("rmat-4000v-16000e", 20, 20).unwrap();
    let b = engine.partitions("RMAT-4000v-16000e-128f", 20, 20).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "same spec must share one cache entry");
    assert_eq!(engine.dataset_builds(), 1);
    assert_eq!(engine.partition_builds(), 1);
    let c = engine.partitions("rmat-4000v-16000e", 10, 10).unwrap();
    assert!(!Arc::ptr_eq(&a, &c));
    assert_eq!(engine.dataset_builds(), 1, "dataset shared across shapes");
    assert_eq!(engine.partition_builds(), 2);
    // A different seed is a different dataset.
    let d = engine.partitions("rmat-4000v-16000e-77s", 20, 20).unwrap();
    assert!(!Arc::ptr_eq(&a, &d));
    assert_eq!(engine.dataset_builds(), 2);
}

#[test]
fn large_graph_tier_simulates_gcn_and_gat_end_to_end() {
    // Acceptance: a named million-edge dataset runs end-to-end through
    // BatchEngine::run for both model families, sharing one generation and
    // one (dataset, V, N) partition set.
    let engine = BatchEngine::new();
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    for kind in [ModelKind::Gcn, ModelKind::Gat] {
        let r = engine
            .run(&SimRequest::new(kind, "ogbn-arxiv-syn", cfg, flags))
            .expect("ogbn-arxiv-syn simulates end-to-end");
        assert!(r.metrics.latency_s > 0.0, "{kind:?}");
        assert!(r.metrics.energy_j > 0.0, "{kind:?}");
        assert!(r.metrics.ops > 0, "{kind:?}");
    }
    assert_eq!(engine.dataset_builds(), 1, "one generation for both models");
    assert_eq!(engine.partition_builds(), 1, "one partition set for both models");
    let ds = engine.dataset("ogbn-arxiv-syn").unwrap();
    assert_eq!(ds.graphs[0].n_vertices, 169_343);
    assert_eq!(ds.graphs[0].n_edges(), 1_166_243);
}

#[test]
fn sweep_reuses_partitions_and_reports_per_point_failures() {
    let engine = BatchEngine::new();
    let workloads = dse::workload_set(true).unwrap();
    let base = GhostConfig::paper_optimal();
    // Every grid point shares (V, N) = (20, 20); the quick workload set is
    // {Cora × 3 models, Proteins}, i.e. two distinct datasets.
    let grid = [
        base,
        GhostConfig { t_r: 11, ..base },
        GhostConfig { r_r: 12, ..base },
        GhostConfig { r_c: 25, ..base }, // infeasible: > 20 coherent MRs
    ];
    let report = dse::explore_with_engine(&engine, &grid, &workloads);
    assert_eq!(report.points.len(), 3);
    assert_eq!(report.failures.len(), 1);
    assert_eq!(report.failures[0].cfg, grid[3]);
    assert!(matches!(report.failures[0].error, SimError::InvalidConfig(_)));
    assert_eq!(
        engine.partition_builds(),
        2,
        "one partition set per distinct (dataset, V, N) across the whole sweep"
    );
    // Frontier sorted ascending by EPB/GOPS, best() is the head.
    for w in report.points.windows(2) {
        assert!(w[0].epb_per_gops <= w[1].epb_per_gops);
    }
    assert_eq!(
        report.best().unwrap().epb_per_gops,
        report.points[0].epb_per_gops
    );
}
