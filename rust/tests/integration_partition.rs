//! Integration tests of the buffer-and-partition preprocessing over the
//! full Table-2 dataset suite.

use ghost::graph::datasets::{Dataset, ALL_DATASETS};
use ghost::graph::partition::PartitionMatrix;

#[test]
fn every_dataset_partitions_cleanly() {
    for spec in ALL_DATASETS {
        let ds = Dataset::generate(spec);
        for g in &ds.graphs {
            let pm = PartitionMatrix::build(g, 20, 20);
            assert_eq!(pm.total_edges(), g.n_edges() as u64, "{}", spec.name);
            assert!(pm.nonzero_blocks() <= pm.total_block_slots());
            assert!(pm.total_distinct_source_fetches() <= pm.total_edges());
        }
    }
}

#[test]
fn sparse_datasets_skip_most_blocks() {
    // The all-zero-block skip is the point of §3.4.1: on the sparse
    // citation graphs most V×N slots must be empty.
    for name in ["Cora", "PubMed", "Citeseer"] {
        let ds = Dataset::by_name(name).unwrap();
        let pm = PartitionMatrix::build(&ds.graphs[0], 20, 20);
        assert!(pm.skip_ratio() > 0.5, "{name}: skip ratio {}", pm.skip_ratio());
    }
}

#[test]
fn denser_graph_skips_fewer_blocks() {
    let cora = Dataset::by_name("Cora").unwrap();
    let amazon = Dataset::by_name("Amazon").unwrap(); // 10× denser
    let pm_c = PartitionMatrix::build(&cora.graphs[0], 20, 20);
    let pm_a = PartitionMatrix::build(&amazon.graphs[0], 20, 20);
    assert!(pm_a.skip_ratio() < pm_c.skip_ratio());
}

#[test]
fn partition_parameters_change_block_granularity() {
    let ds = Dataset::by_name("Citeseer").unwrap();
    let g = &ds.graphs[0];
    let fine = PartitionMatrix::build(g, 10, 10);
    let coarse = PartitionMatrix::build(g, 40, 40);
    assert!(fine.n_output_groups() > coarse.n_output_groups());
    assert_eq!(fine.total_edges(), coarse.total_edges());
    // Finer blocks skip a larger fraction of slots on a sparse graph.
    assert!(fine.skip_ratio() > coarse.skip_ratio());
}

#[test]
fn group_plans_cover_every_vertex_group() {
    let ds = Dataset::by_name("Cora").unwrap();
    let g = &ds.graphs[0];
    let pm = PartitionMatrix::build(g, 20, 20);
    assert_eq!(pm.n_output_groups(), g.n_vertices.div_ceil(20));
    for (i, (grp, blocks)) in pm.iter_groups().enumerate() {
        assert_eq!(grp.out_group as usize, i);
        assert_eq!(blocks.len(), grp.n_blocks as usize);
        // Max lane degree bounds every block's worth of edges.
        let block_edges: u32 = blocks.iter().map(|b| b.n_edges).sum();
        assert_eq!(block_edges, grp.total_edges);
    }
}

#[test]
fn flat_blocks_build_matches_serial_reference_on_all_table2_datasets() {
    // The parallel flat-blocks builder must produce byte-identical
    // partition plans to the single-threaded reference, on every graph of
    // every Table-2 dataset (Amazon crosses the parallel threshold; the
    // rest pin the serial path).
    for spec in ALL_DATASETS {
        let ds = Dataset::generate(spec);
        for g in &ds.graphs {
            let par = PartitionMatrix::build(g, 20, 20);
            let ser = PartitionMatrix::build_serial(g, 20, 20);
            assert_eq!(par, ser, "{}", spec.name);
        }
    }
}

#[test]
fn flat_blocks_build_matches_serial_on_a_million_edge_graph() {
    // The scale the tentpole targets: >=1M edges, parallel path.
    let ds = Dataset::by_name("rmat-120000v-1000000e").unwrap();
    let g = &ds.graphs[0];
    assert!(g.n_edges() >= 1_000_000);
    let par = PartitionMatrix::build(g, 20, 20);
    let ser = PartitionMatrix::build_serial(g, 20, 20);
    assert_eq!(par, ser);
    assert_eq!(par.total_edges(), g.n_edges() as u64);
}

#[test]
fn multi_graph_dataset_partitions_are_small() {
    let ds = Dataset::by_name("Mutag").unwrap();
    for g in &ds.graphs {
        let pm = PartitionMatrix::build(g, 20, 20);
        // ~18-node graphs fit in one or two output groups.
        assert!(pm.n_output_groups() <= 2, "groups: {}", pm.n_output_groups());
    }
}
