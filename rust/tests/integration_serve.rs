//! Integration tests for the online-serving subsystem: the determinism
//! guarantee (same seed ⇒ bit-identical metrics, regardless of engine
//! worker count), the serving sanity laws (utilization ≤ 1, ordered
//! percentiles, closed-loop throughput ≤ fleet capacity), conservation
//! (every offered request completes), and the policy semantics
//! (graph-affinity routing reprograms weights less than round-robin).

use ghost::coordinator::{BatchEngine, SimError, SimRequest};
use ghost::gnn::models::ModelKind;
use ghost::serve::{
    self, simulate_with_profiles, ArrivalProcess, BatchPolicy, RoutePolicy, ServeConfig,
    TenantMix, TenantProfile, TrafficSpec,
};

fn two_tenant_mix() -> TenantMix {
    TenantMix::new(vec![
        TenantProfile::new(ModelKind::Gcn, "Cora", 3.0),
        TenantProfile::new(ModelKind::Gat, "Citeseer", 1.0),
    ])
    .unwrap()
}

fn open(rps: f64) -> TrafficSpec {
    TrafficSpec::Open { process: ArrivalProcess::Poisson, rps }
}

#[test]
fn same_seed_identical_metrics_across_worker_counts() {
    // The acceptance pin: one ServeConfig, two fresh engines, profile
    // resolution fanned over 1 vs 4 workers — every metric (compared via
    // the full serialized report) must be bit-identical.
    let mut cfg = ServeConfig::new(two_tenant_mix(), open(3000.0));
    cfg.accelerators = 3;
    cfg.route = RoutePolicy::GraphAffinity;
    cfg.batch = BatchPolicy::MaxBatchOrWait { max_batch: 4, max_wait_s: 5e-4 };
    cfg.duration_s = 0.5;
    cfg.seed = 7;
    cfg.slo_s = Some(5e-3);
    let e1 = BatchEngine::new();
    let r1 = serve::simulate_with_workers(&e1, &cfg, 1).expect("serial resolve");
    let e4 = BatchEngine::new();
    let r4 = serve::simulate_with_workers(&e4, &cfg, 4).expect("parallel resolve");
    assert_eq!(
        r1.to_json().to_string(),
        r4.to_json().to_string(),
        "worker count changed the serving metrics"
    );
    // And a third run on a *shared* (already warm) engine agrees too.
    let r_again = serve::simulate_with_workers(&e4, &cfg, 2).expect("warm resolve");
    assert_eq!(r1.to_json().to_string(), r_again.to_json().to_string());
}

#[test]
fn different_seeds_give_different_streams() {
    let mut cfg = ServeConfig::new(two_tenant_mix(), open(2000.0));
    cfg.duration_s = 0.3;
    cfg.accelerators = 2;
    let engine = BatchEngine::new();
    let a = serve::simulate(&engine, &cfg).unwrap();
    cfg.seed = 8;
    let b = serve::simulate(&engine, &cfg).unwrap();
    assert_ne!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "seed must steer the arrival stream"
    );
}

#[test]
fn sanity_laws_hold_under_open_loop_load() {
    // The acceptance workload shape: 4 accelerators at high rps.
    let mix = TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap();
    let mut cfg = ServeConfig::new(mix, open(20_000.0));
    cfg.accelerators = 4;
    cfg.duration_s = 1.0;
    cfg.seed = 7;
    let engine = BatchEngine::new();
    let r = serve::simulate(&engine, &cfg).unwrap();
    // Conservation: the fleet drains everything that arrived.
    assert!(r.offered > 10_000, "offered only {}", r.offered);
    assert_eq!(r.offered, r.completed);
    // Utilization is a busy-time fraction of the makespan.
    for a in &r.accels {
        assert!((0.0..=1.0).contains(&a.utilization), "utilization {}", a.utilization);
    }
    assert!(r.fleet_utilization() > 0.0);
    // Percentiles are ordered and positive.
    let l = r.latency;
    assert!(l.min_s > 0.0);
    assert!(l.min_s <= l.p50_s && l.p50_s <= l.p95_s);
    assert!(l.p95_s <= l.p99_s && l.p99_s <= l.p999_s && l.p999_s <= l.max_s);
    // Latency can never undercut the bare service time.
    let profile = engine
        .service_profile(&SimRequest::new(
            ModelKind::Gcn,
            "Cora",
            cfg.accel_cfg,
            cfg.flags,
        ))
        .unwrap();
    assert!(l.min_s >= profile.per_request_s() - 1e-15);
    // Throughput is bounded by what the fleet can physically serve.
    let capacity = cfg.accelerators as f64 / profile.per_request_s();
    assert!(
        r.throughput_rps <= capacity * (1.0 + 1e-9),
        "throughput {} exceeds capacity {capacity}",
        r.throughput_rps
    );
    assert!(r.makespan_s >= r.duration_s);
    assert!(r.energy_j > 0.0);
}

#[test]
fn closed_loop_throughput_bounded_by_fleet_capacity() {
    // Zero think time saturates the fleet: with more clients than
    // accelerators, throughput pins at fleet capacity and must never
    // exceed it.
    let mix = TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap();
    let mut cfg =
        ServeConfig::new(mix, TrafficSpec::Closed { clients: 8, mean_think_s: 0.0 });
    cfg.accelerators = 2;
    cfg.duration_s = 0.05;
    let engine = BatchEngine::new();
    let r = serve::simulate(&engine, &cfg).unwrap();
    let profile = engine
        .service_profile(&SimRequest::new(
            ModelKind::Gcn,
            "Cora",
            cfg.accel_cfg,
            cfg.flags,
        ))
        .unwrap();
    let capacity = cfg.accelerators as f64 / profile.per_request_s();
    assert!(r.completed > 0);
    assert!(
        r.throughput_rps <= capacity * (1.0 + 1e-9),
        "closed-loop throughput {} exceeds fleet capacity {capacity}",
        r.throughput_rps
    );
    // Saturated: the fleet should be near fully busy.
    assert!(r.fleet_utilization() > 0.5, "utilization {}", r.fleet_utilization());
}

#[test]
fn affinity_routing_reprograms_less_than_round_robin() {
    // Two tenants on two accelerators: affinity pins each tenant to the
    // accelerator holding its partitions (2 programs total); round-robin
    // interleaves tenants everywhere and keeps reprogramming.
    let mut cfg = ServeConfig::new(two_tenant_mix(), open(4000.0));
    cfg.accelerators = 2;
    cfg.duration_s = 0.25;
    let engine = BatchEngine::new();
    cfg.route = RoutePolicy::GraphAffinity;
    let affinity = serve::simulate(&engine, &cfg).unwrap();
    cfg.route = RoutePolicy::RoundRobin;
    let rr = serve::simulate(&engine, &cfg).unwrap();
    assert!(
        affinity.total_weight_programs() < rr.total_weight_programs(),
        "affinity {} vs round-robin {} weight programs",
        affinity.total_weight_programs(),
        rr.total_weight_programs()
    );
    assert_eq!(affinity.offered, affinity.completed);
    assert_eq!(rr.offered, rr.completed);
}

#[test]
fn batching_amortizes_weight_programs_in_multi_tenant_interleaving() {
    // On a single accelerator, tenant interleaving forces a reprogram on
    // every tenant switch; batching coalesces same-tenant runs, so larger
    // batches mean fewer programs per served request.
    let mut cfg = ServeConfig::new(two_tenant_mix(), open(4000.0));
    cfg.accelerators = 1;
    cfg.duration_s = 0.2;
    let engine = BatchEngine::new();
    cfg.batch = BatchPolicy::Immediate;
    let immediate = serve::simulate(&engine, &cfg).unwrap();
    cfg.batch = BatchPolicy::MaxBatchOrWait { max_batch: 16, max_wait_s: 2e-3 };
    let batched = serve::simulate(&engine, &cfg).unwrap();
    let imm_rate =
        immediate.total_weight_programs() as f64 / immediate.completed.max(1) as f64;
    let bat_rate = batched.total_weight_programs() as f64 / batched.completed.max(1) as f64;
    assert!(
        bat_rate < imm_rate,
        "batching must cut reprograms/request: immediate {imm_rate}, batched {bat_rate}"
    );
    // Batches actually formed.
    assert!(batched.total_batches() < batched.completed);
    // The energy bill reflects the skipped weight programs: same request
    // stream (same seed), fewer stagings, strictly less energy.
    assert_eq!(immediate.offered, batched.offered, "same stream");
    assert!(
        batched.energy_j < immediate.energy_j,
        "amortized weight programming must cut energy: immediate {} J, batched {} J",
        immediate.energy_j,
        batched.energy_j
    );
}

#[test]
fn degenerate_hand_built_profiles_rejected() {
    use ghost::coordinator::ServiceProfile;
    let mix = TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap();
    let cfg = ServeConfig::new(mix, TrafficSpec::Closed { clients: 1, mean_think_s: 0.0 });
    // per_request_s() == 0 would stall simulated time forever.
    let stalled = ServiceProfile {
        latency_s: 1e-3,
        weight_stage_s: 1e-3,
        energy_j: 1e-6,
        weight_stage_energy_j: 0.0,
    };
    assert!(matches!(
        simulate_with_profiles(&cfg, &[stalled]),
        Err(SimError::InvalidConfig(_))
    ));
    // NaN anywhere poisons every event time and metric.
    let nan = ServiceProfile {
        latency_s: f64::NAN,
        weight_stage_s: 0.0,
        energy_j: 1e-6,
        weight_stage_energy_j: 0.0,
    };
    assert!(matches!(
        simulate_with_profiles(&cfg, &[nan]),
        Err(SimError::InvalidConfig(_))
    ));
}

#[test]
fn slo_attainment_reported_and_bounded() {
    let mix = TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap();
    let mut cfg = ServeConfig::new(mix, open(2000.0));
    cfg.accelerators = 2;
    cfg.duration_s = 0.2;
    cfg.slo_s = Some(10e-3);
    cfg.batch = BatchPolicy::SloAware { slo_s: 10e-3, max_batch: 8 };
    let engine = BatchEngine::new();
    let r = serve::simulate(&engine, &cfg).unwrap();
    let att = r.slo_attainment.expect("SLO set, attainment reported");
    assert!((0.0..=1.0).contains(&att));
    for t in &r.tenants {
        let ta = t.slo_attainment.expect("per-tenant attainment");
        assert!((0.0..=1.0).contains(&ta));
    }
}

#[test]
fn bursty_and_diurnal_streams_serve_end_to_end() {
    let mix = TenantMix::new(vec![TenantProfile::new(ModelKind::Gcn, "Cora", 1.0)]).unwrap();
    for process in [
        ArrivalProcess::Bursty { burst_factor: 4.0, mean_calm_s: 0.05, mean_burst_s: 0.02 },
        ArrivalProcess::Diurnal { period_s: 0.2, amplitude: 0.8 },
    ] {
        let mut cfg = ServeConfig::new(
            mix.clone(),
            TrafficSpec::Open { process, rps: 3000.0 },
        );
        cfg.accelerators = 2;
        cfg.duration_s = 0.2;
        let engine = BatchEngine::new();
        let r = serve::simulate(&engine, &cfg).unwrap();
        assert!(r.offered > 100, "{process:?}: offered {}", r.offered);
        assert_eq!(r.offered, r.completed, "{process:?}");
        assert!(r.latency.p50_s <= r.latency.p99_s, "{process:?}");
    }
}

#[test]
fn serving_shares_the_engine_caches_across_sweeps() {
    // A fleet-size sweep over one mix must resolve each tenant profile
    // once and build each (dataset, V, N) partition set once.
    let engine = BatchEngine::new();
    let mut total = 0u64;
    for accels in [1, 2, 4] {
        let mut cfg = ServeConfig::new(two_tenant_mix(), open(1000.0));
        cfg.accelerators = accels;
        cfg.duration_s = 0.1;
        let r = serve::simulate(&engine, &cfg).unwrap();
        total += r.completed;
    }
    assert!(total > 0);
    assert_eq!(engine.profile_builds(), 2, "one simulation per tenant for the whole sweep");
    assert_eq!(engine.dataset_builds(), 2);
    assert_eq!(engine.partition_builds(), 2);
}

#[test]
fn invalid_serve_configs_are_structured_errors() {
    let mut cfg = ServeConfig::new(two_tenant_mix(), open(1000.0));
    cfg.accelerators = 0;
    let engine = BatchEngine::new();
    assert!(matches!(
        serve::simulate(&engine, &cfg),
        Err(SimError::InvalidConfig(_))
    ));
    // Profile slice length must match the mix.
    let good = ServeConfig::new(two_tenant_mix(), open(1000.0));
    assert!(matches!(
        simulate_with_profiles(&good, &[]),
        Err(SimError::InvalidConfig(_))
    ));
}
