//! Bench: regenerates Fig. 8 — the orchestration/scheduling optimization
//! sensitivity analysis — printing normalized energy per combination, and
//! times the full 9-combination × 16-workload evaluation.

use ghost::config::GhostConfig;
use ghost::coordinator::{simulate, OptFlags};
use ghost::figures;
use ghost::gnn::models::ModelKind;
use ghost::util::bench::{bench, black_box, time_once};

fn main() {
    let cfg = GhostConfig::paper_optimal();
    let rows = time_once("fig8_full_evaluation", || figures::fig8(cfg).unwrap());
    println!("== Fig. 8: normalized energy (baseline = 1.0) ==");
    for r in &rows {
        println!("  {:<22} mean {:.3} ({:.2}x reduction)", r.label, r.mean, 1.0 / r.mean);
    }

    bench("simulate_gcn_cora_default", 2, 30, || {
        black_box(simulate(ModelKind::Gcn, "Cora", cfg, OptFlags::ghost_default()).unwrap());
    });
    bench("simulate_gcn_cora_baseline", 2, 30, || {
        black_box(simulate(ModelKind::Gcn, "Cora", cfg, OptFlags::baseline()).unwrap());
    });
    bench("simulate_gin_proteins_default", 1, 10, || {
        black_box(simulate(ModelKind::Gin, "Proteins", cfg, OptFlags::ghost_default()).unwrap());
    });
}
