//! Bench: regenerates Figs. 10, 11 and 12 — the GHOST vs GPU/TPU/CPU/GNN-
//! accelerator comparison — printing the per-platform geomean ratios and
//! per-workload detail rows, and timing the full comparison pipeline.

use ghost::config::GhostConfig;
use ghost::figures;
use ghost::util::bench::time_once;

fn main() {
    let cfg = GhostConfig::paper_optimal();
    let summary = time_once("fig10_11_12_summary", || figures::comparison_summary(cfg).unwrap());
    println!("== Figs. 10-12: GHOST vs platforms (geomean, >1 = GHOST wins) ==");
    println!(
        "  {:<10} {:>12} {:>12} {:>14}",
        "Platform", "GOPS ratio", "EPB ratio", "EPB/GOPS ratio"
    );
    for r in &summary {
        println!(
            "  {:<10} {:>11.1}x {:>11.1}x {:>13.2e}",
            r.platform, r.gops_ratio, r.epb_ratio, r.epb_gops_ratio
        );
    }

    println!("\n== per-workload detail (Fig. 10 series) ==");
    let detail = time_once("fig10_detail", || figures::comparison_detail(cfg).unwrap());
    for (kind, ds, ghost_m, rows) in &detail {
        print!("  {:<10} {:<12} GHOST {:>9.1} GOPS |", kind.name(), ds, ghost_m.gops());
        for (name, m) in rows {
            print!(" {name} {:.1}x", ghost_m.gops() / m.gops());
        }
        println!();
    }
}
