//! Bench: offline preprocessing at million-edge scale.
//!
//! Measures the parallel flat-blocks [`PartitionMatrix::build`] against the
//! single-threaded [`PartitionMatrix::build_serial`] reference on a ≥1M-edge
//! R-MAT graph (asserting byte-identical plans first), plus large-tier
//! dataset generation and engine-cached end-to-end simulation. Acceptance
//! target: ≥2× build speedup on ≥4 cores.

use ghost::config::GhostConfig;
use ghost::coordinator::{BatchEngine, OptFlags, SimRequest};
use ghost::gnn::models::ModelKind;
use ghost::graph::datasets::Dataset;
use ghost::graph::partition::PartitionMatrix;
use ghost::util::bench::{bench, black_box, time_once};

fn main() {
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!("partition_scale: {cores} hardware threads");

    // A ~1.3M-edge graph from the parameterized R-MAT tier.
    let ds = time_once("generate_rmat_200k_v_1.3M_e", || {
        Dataset::by_name("rmat-200000v-1300000e").expect("rmat spec parses")
    });
    let g = &ds.graphs[0];
    println!("graph: {} vertices, {} edges", g.n_vertices, g.n_edges());
    assert!(g.n_edges() >= 1_000_000, "bench graph must have >=1M edges");

    // Byte-identical plans before timing anything.
    let serial_pm = PartitionMatrix::build_serial(g, 20, 20);
    let parallel_pm = PartitionMatrix::build(g, 20, 20);
    assert_eq!(serial_pm, parallel_pm, "parallel build must equal the serial reference");
    println!(
        "plan: {} output groups, {} non-empty blocks, skip ratio {:.3}",
        serial_pm.n_output_groups(),
        serial_pm.nonzero_blocks(),
        serial_pm.skip_ratio()
    );
    drop((serial_pm, parallel_pm));

    let s = bench("partition_build_serial_1.3M_edges", 1, 7, || {
        black_box(PartitionMatrix::build_serial(g, 20, 20));
    });
    let p = bench("partition_build_parallel_1.3M_edges", 1, 7, || {
        black_box(PartitionMatrix::build(g, 20, 20));
    });
    let speedup = s.median.as_secs_f64() / p.median.as_secs_f64();
    println!(
        "parallel partition-build speedup: {speedup:.2}x on {cores} threads \
         (acceptance: >=2x on >=4 cores)"
    );

    // The named large tier end-to-end through the engine: cold includes
    // generation + partitioning, warm is pure simulation.
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    let engine = BatchEngine::new();
    for kind in [ModelKind::Gcn, ModelKind::Gat] {
        let req = SimRequest::new(kind, "ogbn-arxiv-syn", cfg, flags);
        let label_cold = format!("engine_ogbn_arxiv_syn_{}_cold", kind.name());
        time_once(&label_cold, || {
            black_box(engine.run(&req).expect("ogbn-arxiv-syn simulates"));
        });
        let label_warm = format!("engine_ogbn_arxiv_syn_{}_warm", kind.name());
        bench(&label_warm, 1, 5, || {
            black_box(engine.run(&req).expect("ogbn-arxiv-syn simulates"));
        });
    }
    println!(
        "partition sets built: {} (GCN and GAT share the (dataset, V, N) key)",
        engine.partition_builds()
    );

    // Multi-graph generation fans per-graph derived seeds over the pool.
    time_once("generate_proteins_1113_graphs", || {
        black_box(Dataset::by_name("Proteins").expect("table-2 dataset"));
    });
}
