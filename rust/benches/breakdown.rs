//! Bench: regenerates Fig. 9 — per-block latency breakdown across all 16
//! model × dataset workloads — and times per-model simulation.

use ghost::config::GhostConfig;
use ghost::coordinator::{simulate, OptFlags};
use ghost::figures;
use ghost::gnn::models::ModelKind;
use ghost::util::bench::{bench, black_box, time_once};

fn main() {
    let cfg = GhostConfig::paper_optimal();
    let rows = time_once("fig9_full_evaluation", || figures::fig9(cfg).unwrap());
    println!("== Fig. 9: latency breakdown ==");
    println!("  {:<10} {:<12} {:>9} {:>9} {:>9}", "Model", "Dataset", "Agg", "Comb", "Upd");
    for r in &rows {
        println!(
            "  {:<10} {:<12} {:>8.1}% {:>8.1}% {:>8.1}%",
            r.model,
            r.dataset,
            r.aggregate * 100.0,
            r.combine * 100.0,
            r.update * 100.0
        );
    }

    for (kind, ds) in
        [(ModelKind::Gcn, "PubMed"), (ModelKind::Gat, "Amazon"), (ModelKind::GraphSage, "Cora")]
    {
        bench(&format!("simulate_{}_{ds}", kind.name()), 1, 15, || {
            black_box(simulate(kind, ds, cfg, OptFlags::ghost_default()).unwrap());
        });
    }
}
