//! Bench: regenerates Fig. 7(c) — the architectural [N,V,Rr,Rc,Tr] sweep —
//! printing the EPB/GOPS frontier and the rank of the paper's optimum, and
//! times the full parallel sweep through the BatchEngine, the
//! serial-vs-parallel grid speedup (same warm engine, worker count
//! pinned), the delta-re-costing vs full-rebuild throughput in points/sec
//! (asserted >=10x), plus warm- and cold-cache single-configuration
//! evaluations.

use std::time::Instant;

use ghost::config::GhostConfig;
use ghost::coordinator::dse;
use ghost::coordinator::BatchEngine;
use ghost::util::bench::{bench, black_box, time_once};
use ghost::util::json::{obj, Json};
use ghost::util::parallel::default_workers;

fn main() {
    let workloads = dse::workload_set(true).expect("table-2 workload set"); // one dataset per model
    let grid = dse::default_grid();
    let engine = BatchEngine::new();
    println!("grid size: {} configurations x {} workloads", grid.len(), workloads.len());

    let report =
        time_once("fig7c_full_sweep", || dse::explore_with_engine(&engine, &grid, &workloads));
    println!("== Fig. 7(c): top configurations by EPB/GOPS ==");
    for (i, p) in report.points.iter().take(8).enumerate() {
        println!(
            "  #{:<2} [{}, {}, {}, {}, {}]  EPB/GOPS {:.3e}",
            i + 1,
            p.cfg.n,
            p.cfg.v,
            p.cfg.r_r,
            p.cfg.r_c,
            p.cfg.t_r,
            p.epb_per_gops
        );
    }
    if let Some(rank) = report.points.iter().position(|p| p.cfg == GhostConfig::paper_optimal()) {
        println!("  paper point [20,20,18,7,17] ranks #{} of {}", rank + 1, report.points.len());
    }
    if !report.failures.is_empty() {
        println!("  {} point(s) failed or were filtered:", report.failures.len());
        for f in report.failures.iter().take(5) {
            println!("    {:?}: {}", f.cfg, f.error);
        }
    }
    println!(
        "partition sets built: {} (once per distinct (dataset, V, N) across the sweep)",
        engine.partition_builds()
    );

    // Serial vs parallel grid evaluation on the warm engine (partitions
    // all cached by the sweep above), so the speedup isolates the
    // simulation fan-out itself rather than preprocessing.
    let workers = default_workers();
    let t0 = Instant::now();
    black_box(dse::explore_with_engine_workers(&engine, &grid, &workloads, 1));
    let serial = t0.elapsed();
    let t0 = Instant::now();
    black_box(dse::explore_with_engine_workers(&engine, &grid, &workloads, workers));
    let parallel = t0.elapsed();
    println!(
        "bench fig7c_grid_serial_1worker            single run {serial:>12?}"
    );
    println!(
        "bench fig7c_grid_parallel_{workers}workers          single run {parallel:>12?}"
    );
    println!(
        "parallel sweep speedup: {:.2}x over serial on {workers} workers",
        serial.as_secs_f64() / parallel.as_secs_f64().max(1e-12)
    );

    // Delta re-costing vs full rebuild, both pinned to one worker so the
    // ratio isolates the algorithm rather than the thread pool. The full
    // path re-lowers every (cfg, workload) plan from scratch — exactly
    // what the GHOST_DSE_DELTA=0 sweep does per point — while the delta
    // path Gray-walks the grid and patches only provenance-affected
    // lanes. Both run on the warm engine, so partition builds are out of
    // the picture on either side.
    assert!(
        dse::delta_evaluation_enabled(),
        "unset GHOST_DSE_DELTA before running this bench: the delta-vs-full \
         comparison below needs the delta path on"
    );
    let valid: Vec<GhostConfig> =
        grid.iter().copied().filter(|c| c.validate().is_ok()).collect();
    let t0 = Instant::now();
    for &cfg in &valid {
        black_box(dse::evaluate_with_engine(&engine, cfg, &workloads).ok());
    }
    let full = t0.elapsed();
    let t0 = Instant::now();
    let delta_report =
        black_box(dse::explore_with_engine_workers(&engine, &grid, &workloads, 1));
    let delta = t0.elapsed();
    let full_pps = valid.len() as f64 / full.as_secs_f64().max(1e-12);
    let delta_pps = valid.len() as f64 / delta.as_secs_f64().max(1e-12);
    println!(
        "full rebuild:  {full_pps:>10.1} points/sec ({} valid points in {full:?})",
        valid.len()
    );
    println!(
        "delta sweep:   {delta_pps:>10.1} points/sec ({} rebuilds, {} lane patches)",
        delta_report.delta.rebuilds, delta_report.delta.patches
    );
    println!("delta re-costing speedup: {:.1}x over full rebuild", delta_pps / full_pps);
    assert!(
        delta_pps >= 10.0 * full_pps,
        "delta sweep must clear 10x the full-rebuild throughput: \
         {delta_pps:.1} vs {full_pps:.1} points/sec"
    );

    let json = obj(vec![
        ("grid_points", Json::Num(grid.len() as f64)),
        ("valid_points", Json::Num(valid.len() as f64)),
        ("workloads", Json::Num(workloads.len() as f64)),
        ("full_points_per_s", Json::Num(full_pps)),
        ("delta_points_per_s", Json::Num(delta_pps)),
        ("speedup", Json::Num(delta_pps / full_pps)),
        ("rebuilds", Json::Num(delta_report.delta.rebuilds as f64)),
        ("patches", Json::Num(delta_report.delta.patches as f64)),
    ]);
    std::fs::write("BENCH_dse.json", format!("{json}\n")).expect("write BENCH_dse.json");
    println!("wrote BENCH_dse.json");

    // Warm cache: every (dataset, V, N) the paper point needs already sits
    // in the engine from the sweep above.
    bench("fig7c_single_config_eval_warm", 1, 10, || {
        black_box(
            dse::evaluate_with_engine(&engine, GhostConfig::paper_optimal(), &workloads)
                .expect("paper point evaluates"),
        );
    });
    // Cold reference: rebuilds every partition from scratch, the cost the
    // engine amortizes away.
    bench("fig7c_single_config_eval_cold", 1, 10, || {
        black_box(
            dse::evaluate(GhostConfig::paper_optimal(), &workloads)
                .expect("paper point evaluates"),
        );
    });
}
