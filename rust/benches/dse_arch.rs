//! Bench: regenerates Fig. 7(c) — the architectural [N,V,Rr,Rc,Tr] sweep —
//! printing the EPB/GOPS frontier and the rank of the paper's optimum, and
//! times a single-configuration evaluation plus the full parallel sweep.

use ghost::config::GhostConfig;
use ghost::coordinator::dse;
use ghost::util::bench::{bench, black_box, time_once};

fn main() {
    let workloads = dse::workload_set(true); // one dataset per model
    let grid = dse::default_grid();
    println!("grid size: {} configurations x {} workloads", grid.len(), workloads.len());

    let points = time_once("fig7c_full_sweep", || dse::explore(&grid, &workloads));
    println!("== Fig. 7(c): top configurations by EPB/GOPS ==");
    for (i, p) in points.iter().take(8).enumerate() {
        println!(
            "  #{:<2} [{}, {}, {}, {}, {}]  EPB/GOPS {:.3e}",
            i + 1,
            p.cfg.n,
            p.cfg.v,
            p.cfg.r_r,
            p.cfg.r_c,
            p.cfg.t_r,
            p.epb_per_gops
        );
    }
    if let Some(rank) = points.iter().position(|p| p.cfg == GhostConfig::paper_optimal()) {
        println!("  paper point [20,20,18,7,17] ranks #{} of {}", rank + 1, points.len());
    }

    bench("fig7c_single_config_eval", 1, 10, || {
        black_box(dse::evaluate(GhostConfig::paper_optimal(), &workloads));
    });
}
