//! Bench: disabled-path cost of the telemetry spans on the evaluate hot
//! path.
//!
//! `plan::evaluate` is `plan::evaluate_core` plus one [`telemetry::span`]
//! site; with tracing disabled a span is a single relaxed atomic load and
//! no allocation, so the instrumented entry must stay within 5% of the
//! uninstrumented core. Each sample times a burst of evaluations of a
//! mid-size R-MAT plan (big enough that one evaluation is microseconds,
//! not nanoseconds, so scheduler noise doesn't dominate), and the
//! assertion compares the noise-robust per-bench minimum. Results land in
//! `BENCH_telemetry.json` for the CI perf-trajectory artifact.
//!
//! [`telemetry::span`]: ghost::util::telemetry::span

use ghost::config::GhostConfig;
use ghost::coordinator::{plan, BatchEngine, OptFlags, SimRequest};
use ghost::gnn::models::ModelKind;
use ghost::util::bench::{bench, black_box};
use ghost::util::json::{obj, Json};
use ghost::util::telemetry;

const DATASET: &str = "rmat-20000v-120000e";
const WARMUP: u32 = 30;
const ITERS: u32 = 300;
/// Evaluations per timed sample.
const BURST: u32 = 10;
const MAX_OVERHEAD: f64 = 1.05;

fn main() {
    assert!(
        !telemetry::enabled(),
        "unset GHOST_TRACE before running this bench: it measures the \
         disabled path"
    );
    let engine = BatchEngine::new();
    let req = SimRequest::new(
        ModelKind::Gcn,
        DATASET,
        GhostConfig::paper_optimal(),
        OptFlags::ghost_default(),
    );
    let plan = engine.plan(&req).expect("plan build");
    println!("telemetry overhead bench: {BURST} evaluations x {ITERS} samples on {DATASET}");

    let core = bench("evaluate_core (uninstrumented)", WARMUP, ITERS, || {
        for _ in 0..BURST {
            black_box(plan::evaluate_core(black_box(&plan)).expect("evaluate_core"));
        }
    });
    let instrumented = bench("evaluate (span site, disabled)", WARMUP, ITERS, || {
        for _ in 0..BURST {
            black_box(plan::evaluate(black_box(&plan)).expect("evaluate"));
        }
    });

    let core_min_s = core.min.as_secs_f64();
    let instr_min_s = instrumented.min.as_secs_f64();
    let ratio = instr_min_s / core_min_s.max(1e-12);
    println!(
        "disabled-path overhead: {:.2}% (core min {:.3} us, instrumented min {:.3} us per burst)",
        (ratio - 1.0) * 100.0,
        core_min_s * 1e6,
        instr_min_s * 1e6
    );

    let json = obj(vec![
        ("dataset", Json::Str(DATASET.to_string())),
        ("burst", Json::Num(BURST as f64)),
        ("iters", Json::Num(ITERS as f64)),
        ("core_min_s", Json::Num(core_min_s)),
        ("core_median_s", Json::Num(core.median.as_secs_f64())),
        ("instrumented_min_s", Json::Num(instr_min_s)),
        ("instrumented_median_s", Json::Num(instrumented.median.as_secs_f64())),
        ("overhead_ratio", Json::Num(ratio)),
        ("max_overhead_ratio", Json::Num(MAX_OVERHEAD)),
    ]);
    std::fs::write("BENCH_telemetry.json", format!("{json}\n"))
        .expect("write BENCH_telemetry.json");
    println!("wrote BENCH_telemetry.json");

    assert!(
        ratio <= MAX_OVERHEAD,
        "disabled telemetry must cost <=5% on the evaluate hot path: \
         measured {:.2}%",
        (ratio - 1.0) * 100.0
    );
}
