//! Bench: incremental graph-churn maintenance vs cold rebuild on a
//! million-edge R-MAT graph.
//!
//! Each epoch applies one random [`GraphDelta`] batch (pure edge churn —
//! no vertex growth, so the output-group count is stable and every epoch
//! takes the patch path) and times the full incremental pipeline — CSR
//! splice + touched-group partition re-derivation + [`GraphDeltaPlan`]
//! patch + evaluation — against the cold pipeline the patch replaces:
//! re-partitioning the whole graph, rebuilding the [`StagePlan`], and
//! evaluating it. Bit-identity of both the spliced partitions and the
//! patched plan's report is asserted *outside* the timed regions every
//! epoch, and the summed speedup is asserted >= 10x. Results land in
//! `BENCH_churn.json` for the CI perf-trajectory artifact.
//!
//! [`GraphDelta`]: ghost::graph::mutate::GraphDelta
//! [`GraphDeltaPlan`]: ghost::coordinator::GraphDeltaPlan
//! [`StagePlan`]: ghost::coordinator::StagePlan

use std::time::Instant;

use ghost::config::GhostConfig;
use ghost::coordinator::{plan, GraphDeltaPlan, OptFlags};
use ghost::gnn::models::ModelKind;
use ghost::graph::datasets::Dataset;
use ghost::graph::mutate::{self, apply_to_dataset, random_batch};
use ghost::graph::partition::PartitionMatrix;
use ghost::util::bench::black_box;
use ghost::util::json::{obj, Json};
use ghost::util::rng::{mix_seed, Pcg64};

const DATASET: &str = "rmat-131072v-1000000e-32f";
const EPOCHS: usize = 20;
/// Edge operations per epoch: 20 x 250 = 5000 ops, 0.5% of the edge set
/// over the whole run — the "small batch against a big graph" regime the
/// incremental path exists for.
const BATCH: usize = 250;
const ADD_FRACTION: f64 = 0.6;

fn main() {
    assert!(
        !mutate::churn_check_enabled(),
        "unset GHOST_CHURN_CHECK before running this bench: the oracle \
         re-partitions the whole graph inside the timed incremental region"
    );
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    let kind = ModelKind::Gcn;
    let mut dataset = Dataset::by_name(DATASET).expect("parameterized R-MAT spec");
    let n_edges0 = dataset.graphs[0].n_edges();
    println!(
        "churn bench: {} ({} vertices, {} edges), {} epochs x {} ops",
        DATASET, dataset.graphs[0].n_vertices, n_edges0, EPOCHS, BATCH
    );

    let t0 = Instant::now();
    let mut partitions = PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
    println!("bench churn_initial_partition            single run {:>12?}", t0.elapsed());
    let mut delta_plan = GraphDeltaPlan::new(kind, &dataset.spec, cfg, flags, 1);
    let t0 = Instant::now();
    delta_plan.retarget_graph(&dataset, &partitions, None).expect("priming rebuild");
    println!("bench churn_priming_rebuild              single run {:>12?}", t0.elapsed());

    let mut rng = Pcg64::seed_from_u64(mix_seed(2024, 0));
    let mut incremental_s = 0.0f64;
    let mut full_s = 0.0f64;
    let mut per_epoch = Vec::with_capacity(EPOCHS);
    for epoch in 0..EPOCHS {
        let batch = random_batch(&dataset.graphs[0], BATCH, ADD_FRACTION, 0.0, &mut rng);

        // Incremental: splice the CSR + partitions, patch the plan's
        // touched groups, evaluate.
        let t0 = Instant::now();
        let applied = apply_to_dataset(&mut dataset, &mut partitions, 0, &batch)
            .expect("random batches always validate");
        delta_plan
            .retarget_graph(&dataset, &partitions, Some(std::slice::from_ref(&applied)))
            .expect("patch retarget");
        let inc_report = delta_plan.evaluate().expect("patched evaluation");
        let inc = t0.elapsed().as_secs_f64();
        incremental_s += inc;

        // Cold: what serving would pay without the incremental machinery —
        // re-partition the whole mutated graph, rebuild and evaluate the
        // plan from scratch.
        let t0 = Instant::now();
        let cold_partitions = PartitionMatrix::build_all(&dataset.graphs, cfg.v, cfg.n);
        let cold_plan = plan::build(kind, &dataset, &cold_partitions, cfg, flags)
            .expect("cold plan build");
        let cold_report = plan::evaluate(&cold_plan).expect("cold evaluation");
        let full = t0.elapsed().as_secs_f64();
        full_s += full;

        // Bit-identity, release-asserted outside both timed regions.
        assert_eq!(
            partitions, cold_partitions,
            "epoch {epoch}: spliced partitions diverged from a cold build"
        );
        assert_eq!(
            inc_report, cold_report,
            "epoch {epoch}: patched plan diverged from a cold rebuild"
        );
        black_box(&inc_report);
        per_epoch.push((applied.new_n_edges, inc, full));
    }

    let speedup = full_s / incremental_s.max(1e-12);
    println!(
        "incremental: {:>9.3} ms total ({:.3} ms/epoch)",
        incremental_s * 1e3,
        incremental_s * 1e3 / EPOCHS as f64
    );
    println!(
        "cold rebuild:{:>9.3} ms total ({:.3} ms/epoch)",
        full_s * 1e3,
        full_s * 1e3 / EPOCHS as f64
    );
    println!(
        "churn speedup: {speedup:.1}x over cold rebuild ({} rebuilds, {} patches)",
        delta_plan.rebuilds(),
        delta_plan.patches()
    );
    assert_eq!(delta_plan.rebuilds(), 1, "only the priming build may rebuild");
    assert_eq!(delta_plan.patches(), EPOCHS, "every epoch must take the patch path");

    let json = obj(vec![
        ("dataset", Json::Str(DATASET.to_string())),
        ("n_edges_initial", Json::Num(n_edges0 as f64)),
        ("epochs", Json::Num(EPOCHS as f64)),
        ("batch_ops", Json::Num(BATCH as f64)),
        (
            "churn_fraction",
            Json::Num((EPOCHS * BATCH) as f64 / n_edges0 as f64),
        ),
        ("incremental_s", Json::Num(incremental_s)),
        ("full_s", Json::Num(full_s)),
        ("speedup", Json::Num(speedup)),
        ("rebuilds", Json::Num(delta_plan.rebuilds() as f64)),
        ("patches", Json::Num(delta_plan.patches() as f64)),
        (
            "per_epoch",
            Json::Arr(
                per_epoch
                    .iter()
                    .map(|&(edges, inc, full)| {
                        obj(vec![
                            ("n_edges", Json::Num(edges as f64)),
                            ("incremental_s", Json::Num(inc)),
                            ("full_s", Json::Num(full)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_churn.json", format!("{json}\n")).expect("write BENCH_churn.json");
    println!("wrote BENCH_churn.json");

    assert!(
        speedup >= 10.0,
        "incremental maintenance must clear 10x the cold-rebuild cost at \
         <=1% churn: measured {speedup:.1}x"
    );
}
