//! Bench: regenerates Figs. 7(a) and 7(b) — the device-level design-space
//! exploration — and times the sweeps. Prints the feasibility frontiers
//! the paper reports (20 MRs coherent @ 1520 nm, 18 λ non-coherent).

use ghost::photonics::devices::DeviceParams;
use ghost::photonics::dse;
use ghost::util::bench::{bench, black_box};

fn main() {
    let p = DeviceParams::paper();

    println!("== Fig. 7(a): coherent MR-bank DSE ==");
    for lambda in [1520.0, 1530.0, 1540.0, 1550.0, 1560.0, 1570.0] {
        let max = dse::max_feasible_coherent(&p, lambda, 40);
        println!("  lambda {lambda:.0} nm -> max {max} MRs");
    }
    println!("== Fig. 7(b): non-coherent WDM DSE ==");
    println!("  max wavelengths = {}", dse::max_feasible_noncoherent(30));

    let lambdas: Vec<f64> = (0..6).map(|i| 1520.0 + 10.0 * i as f64).collect();
    bench("fig7a_coherent_sweep", 3, 50, || {
        black_box(dse::coherent_sweep(&p, &lambdas, 40));
    });
    bench("fig7b_noncoherent_sweep", 3, 50, || {
        black_box(dse::noncoherent_sweep(30));
    });
}
