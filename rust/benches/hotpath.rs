//! Bench: simulator hot paths — partition construction, per-group stage
//! evaluation, pipeline DP, noise models — plus the PJRT execute path when
//! artifacts are present. This is the §Perf profiling driver.

use ghost::config::GhostConfig;
use ghost::coordinator::{simulate_workload, BatchEngine, OptFlags, SimRequest};
use ghost::gnn::models::ModelKind;
use ghost::graph::datasets::Dataset;
use ghost::graph::partition::PartitionMatrix;
use ghost::photonics::crosstalk::worst_case_heterodyne;
use ghost::photonics::mr::MicroringDesign;
#[cfg(feature = "pjrt")]
use ghost::runtime::Engine;
use ghost::sim;
use ghost::util::bench::{bench, black_box};
use ghost::util::rng::Pcg64;

fn main() {
    // Partition construction on the largest single graph (PubMed).
    let pubmed = Dataset::by_name("PubMed").unwrap();
    bench("partition_build_pubmed", 2, 30, || {
        black_box(PartitionMatrix::build(&pubmed.graphs[0], 20, 20));
    });

    let amazon = Dataset::by_name("Amazon").unwrap();
    bench("partition_build_amazon_238k_edges", 2, 30, || {
        black_box(PartitionMatrix::build(&amazon.graphs[0], 20, 20));
    });

    // Full simulation of the heaviest workloads.
    let cfg = GhostConfig::paper_optimal();
    let flags = OptFlags::ghost_default();
    bench("simulate_pubmed_gcn_e2e", 1, 15, || {
        black_box(simulate_workload(ModelKind::Gcn, &pubmed, cfg, flags).unwrap());
    });
    let proteins = Dataset::by_name("Proteins").unwrap();
    bench("simulate_proteins_gin_1113_graphs", 1, 10, || {
        black_box(simulate_workload(ModelKind::Gin, &proteins, cfg, flags).unwrap());
    });

    // The batch engine's cache: identical request, cold vs warm partition
    // cache (warm skips dataset generation and partitioning entirely).
    let req = SimRequest::new(ModelKind::Gcn, "PubMed", cfg, flags);
    bench("engine_run_pubmed_gcn_cold_cache", 0, 5, || {
        black_box(BatchEngine::new().run(&req).expect("engine run"));
    });
    let engine = BatchEngine::new();
    bench("engine_run_pubmed_gcn_warm_cache", 1, 15, || {
        black_box(engine.run(&req).expect("engine run"));
    });

    // Pipeline DP on a large synthetic schedule.
    let mut rng = Pcg64::seed_from_u64(42);
    let schedule: Vec<Vec<f64>> =
        (0..10_000).map(|_| (0..4).map(|_| rng.next_f64()).collect()).collect();
    bench("pipeline_dp_10k_groups", 3, 100, || {
        black_box(sim::pipelined(&schedule).expect("uniform schedule"));
    });

    // Crosstalk noise model inner loop.
    let mr = MicroringDesign::paper();
    let wavelengths: Vec<f64> = (0..18).map(|i| 1550e-9 + i as f64 * 1e-9).collect();
    bench("heterodyne_noise_18ch", 10, 200, || {
        black_box(worst_case_heterodyne(&mr, &wavelengths));
    });

    // Dataset generation (offline preprocessing path).
    bench("generate_amazon_dataset", 1, 5, || {
        black_box(Dataset::by_name("Amazon").unwrap());
    });

    // PJRT execute path (functional datapath), feature and artifacts
    // permitting.
    #[cfg(feature = "pjrt")]
    {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("gcn_cora.json").exists() {
            match Engine::load(&dir, "gcn_cora") {
                Ok(engine) => {
                    bench("pjrt_execute_gcn_cora", 1, 5, || {
                        black_box(engine.run().expect("execute"));
                    });
                }
                Err(e) => println!("skipping pjrt bench: {e}"),
            }
        } else {
            println!("skipping pjrt bench: run `make artifacts` first");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("skipping pjrt bench: built without the `pjrt` feature");
}
