//! Bench: what the engine's StagePlan cache buys.
//!
//! Runs the Fig. 8 ablation sweep (9 optimization presets) over a
//! three-workload mix three ways:
//!
//! * **cold full** — `simulate_workload` per point: rebuilds partitions
//!   *and* the plan for every point, the cost a sweep without the engine
//!   pays;
//! * **cold plans** — `simulate_with_partitions` with shared partitions:
//!   plan construction + evaluation per point (what the engine pays on a
//!   cache miss);
//! * **cached plans** — `BatchEngine::run` on a warm engine: pure plan
//!   evaluation per point.
//!
//! Acceptance (asserted): the cached-plan sweep is ≥ 2× faster than cold
//! per-point simulation.

use std::time::Instant;

use ghost::config::GhostConfig;
use ghost::coordinator::{
    simulate_with_partitions, simulate_workload, BatchEngine, OptFlags, SimRequest,
};
use ghost::gnn::models::ModelKind;
use ghost::util::bench::black_box;

const WORKLOADS: [(ModelKind, &str); 3] =
    [(ModelKind::Gcn, "PubMed"), (ModelKind::Gat, "Cora"), (ModelKind::Gin, "Mutag")];
const REPS: usize = 5;

fn main() {
    let cfg = GhostConfig::paper_optimal();
    let presets = OptFlags::fig8_presets();
    let engine = BatchEngine::new();

    // Warm every cache tier: datasets, partitions, and one plan per
    // (model, dataset, flags) point of the ablation sweep.
    let reqs: Vec<SimRequest> = WORKLOADS
        .iter()
        .flat_map(|&(kind, ds)| {
            presets.iter().map(move |&flags| SimRequest::new(kind, ds, cfg, flags))
        })
        .collect();
    for r in &reqs {
        engine.run(r).expect("ablation point simulates");
    }
    println!(
        "ablation sweep: {} points ({} workloads x {} presets); plans built: {}",
        reqs.len(),
        WORKLOADS.len(),
        presets.len(),
        engine.plan_builds()
    );

    // Cached plans: every run() is a plan evaluation, zero construction.
    let t0 = Instant::now();
    for _ in 0..REPS {
        for r in &reqs {
            black_box(engine.run(r).expect("cached point simulates"));
        }
    }
    let cached = t0.elapsed();
    assert_eq!(engine.plan_builds(), reqs.len(), "no rebuilds on the warm sweep");

    // Cold plans: shared partitions, but construction + evaluation per
    // point (the engine's cache-miss cost).
    let prepared: Vec<_> = WORKLOADS
        .iter()
        .map(|&(kind, name)| {
            let ds = engine.dataset(name).expect("dataset");
            let pms = engine.partitions_for(&ds, cfg.v, cfg.n).expect("partitions");
            (kind, ds, pms)
        })
        .collect();
    let t0 = Instant::now();
    for _ in 0..REPS {
        for (kind, ds, pms) in &prepared {
            for &flags in &presets {
                black_box(
                    simulate_with_partitions(*kind, ds, pms, cfg, flags)
                        .expect("cold-plan point simulates"),
                );
            }
        }
    }
    let cold_plans = t0.elapsed();

    // Cold full: partitions rebuilt per point too — the uncached sweep.
    let datasets: Vec<_> = prepared.iter().map(|(k, ds, _)| (*k, ds.clone())).collect();
    let t0 = Instant::now();
    for _ in 0..REPS {
        for (kind, ds) in &datasets {
            for &flags in &presets {
                black_box(
                    simulate_workload(*kind, ds, cfg, flags)
                        .expect("cold-full point simulates"),
                );
            }
        }
    }
    let cold_full = t0.elapsed();

    let per = |d: std::time::Duration| d.as_secs_f64() / (REPS * reqs.len()) as f64 * 1e6;
    println!(
        "bench plan_reuse_sweep_cached_plans          total {cached:>12?} ({:.1} us/point)",
        per(cached)
    );
    println!(
        "bench plan_reuse_sweep_cold_plans            total {cold_plans:>12?} ({:.1} us/point)",
        per(cold_plans)
    );
    println!(
        "bench plan_reuse_sweep_cold_full             total {cold_full:>12?} ({:.1} us/point)",
        per(cold_full)
    );
    let vs_plans = cold_plans.as_secs_f64() / cached.as_secs_f64().max(1e-12);
    let vs_full = cold_full.as_secs_f64() / cached.as_secs_f64().max(1e-12);
    println!(
        "cached-plan sweep speedup: {vs_plans:.2}x vs plan rebuilds, \
         {vs_full:.2}x vs cold per-point simulation"
    );
    assert!(
        vs_full >= 2.0,
        "cached-plan ablation sweep must be >= 2x faster than cold per-point \
         simulation (got {vs_full:.2}x)"
    );
}
