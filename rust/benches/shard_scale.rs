//! Bench: sharded multi-chip execution at million-edge scale.
//!
//! Runs a ≥1M-edge R-MAT workload through the sharded plan at 1/2/4/8
//! chips and reports makespan and the communication fraction (remote
//! gathers over the inter-chip link vs total busy time). Acceptance
//! target: the 4-shard makespan beats single-chip (the per-chip recurrence
//! shrinks faster than the RemoteGather barrier cost grows).

use ghost::config::GhostConfig;
use ghost::coordinator::{BatchEngine, OptFlags, SimRequest};
use ghost::gnn::models::ModelKind;
use ghost::util::bench::{bench, black_box, time_once};

fn main() {
    let engine = BatchEngine::new();
    let cfg = GhostConfig::paper_optimal();
    let req = SimRequest::new(
        ModelKind::Gcn,
        "rmat-200000v-1300000e",
        cfg,
        OptFlags::ghost_default(),
    );

    println!("shard_scale: gcn / rmat-200000v-1300000e");
    println!(
        "{:>7} {:>13} {:>13} {:>13} {:>8}",
        "Shards", "Makespan us", "Busy us", "Comm us", "Comm %"
    );
    let mut makespans = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        // Cold: dataset + partition caches are shared across shard counts,
        // so the first iteration pays generation and every later one only
        // the sharded plan build + evaluation.
        let r = time_once(&format!("run_sharded_{shards}_cold"), || {
            engine.run_sharded(&req, shards).expect("sharded run")
        });
        let total_busy_s = r.aggregate_s
            + r.combine_s
            + r.update_s
            + r.kinds.weight_stage.latency_s
            + r.kinds.edge_stream.latency_s
            + r.kinds.remote_gather.latency_s;
        let comm_s = r.kinds.remote_gather.latency_s;
        println!(
            "{:>7} {:>13.3} {:>13.3} {:>13.3} {:>7.2}%",
            shards,
            r.metrics.latency_s * 1e6,
            total_busy_s * 1e6,
            comm_s * 1e6,
            100.0 * comm_s / total_busy_s
        );
        if shards == 1 {
            assert_eq!(comm_s, 0.0, "single-chip plan must not pay remote gathers");
        } else {
            assert!(comm_s > 0.0, "{shards}-shard plan must pay remote gathers");
        }
        makespans.push((shards, r.metrics.latency_s));
    }

    let one = makespans[0].1;
    let four = makespans.iter().find(|(s, _)| *s == 4).unwrap().1;
    println!(
        "4-shard speedup over single chip: {:.2}x (acceptance: >1x)",
        one / four
    );
    assert!(
        four < one,
        "4-shard makespan {four:.6e}s must beat single-chip {one:.6e}s"
    );

    // Warm: plan cached per shard count, so this times pure re-evaluation.
    for shards in [1usize, 4] {
        bench(&format!("run_sharded_{shards}_warm"), 1, 7, || {
            black_box(engine.run_sharded(&req, shards).expect("sharded run"));
        });
    }
    println!(
        "sharded plans built: {} (one per shard count, cached thereafter)",
        engine.sharded_plan_builds()
    );
}
