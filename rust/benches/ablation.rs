//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * channel spacing vs feasible WDM bank size (the paper fixes 1 nm),
//! * Q-factor vs SNR cutoff and tunable range (the paper picks Q = 3100),
//! * execution-lane count V vs latency/power (the Fig. 7(c) axis),
//! * FPV mitigation: direct trimming vs channel remapping (conclusion §5).

use ghost::config::{GhostConfig, N_LEVELS};
use ghost::coordinator::{BatchEngine, OptFlags, SimRequest};
use ghost::gnn::models::ModelKind;
use ghost::photonics::crosstalk::worst_case_heterodyne;
use ghost::photonics::devices::{linear_to_db, DeviceParams};
use ghost::photonics::fpv::{eo_only_yield, FpvModel};
use ghost::photonics::mr::MicroringDesign;
use ghost::photonics::snr::required_snr_db;
use ghost::util::bench::time_once;

fn max_wavelengths_at_spacing(spacing_nm: f64) -> usize {
    let mut best = 0;
    for nw in 2..=40usize {
        let mid = 1550e-9 + spacing_nm * 1e-9 * (nw as f64 - 1.0) / 2.0;
        let mr = MicroringDesign { resonant_wavelength_m: mid, ..MicroringDesign::paper() };
        let wavelengths: Vec<f64> =
            (0..nw).map(|i| 1550e-9 + i as f64 * spacing_nm * 1e-9).collect();
        let noise = worst_case_heterodyne(&mr, &wavelengths);
        let snr = linear_to_db(1.0 / noise);
        if snr >= required_snr_db(&mr, N_LEVELS) {
            best = nw;
        }
    }
    best
}

fn main() {
    println!("== ablation: channel spacing vs WDM capacity (paper: 1 nm) ==");
    time_once("ablation_channel_spacing", || {
        for spacing in [0.5, 0.8, 1.0, 1.5, 2.0] {
            println!("  spacing {spacing:.1} nm -> {} wavelengths", max_wavelengths_at_spacing(spacing));
        }
    });

    println!("\n== ablation: Q-factor vs SNR cutoff & tunable range (paper: 3100) ==");
    time_once("ablation_q_factor", || {
        for q in [1000.0, 2000.0, 3100.0, 5000.0, 10000.0] {
            let mr = MicroringDesign { q_factor: q, ..MicroringDesign::paper() };
            println!(
                "  Q {q:>6.0}: cutoff {:.1} dB, tunable range {:.2} nm",
                required_snr_db(&mr, N_LEVELS),
                mr.tunable_range_m() * 1e9
            );
        }
    });

    println!("\n== ablation: execution lanes V vs latency/power (GCN/Cora) ==");
    // One engine for the sweep: Cora is generated once and each (V, N)
    // partition set is built once, so the loop times simulation, not
    // preprocessing.
    let engine = BatchEngine::new();
    time_once("ablation_lane_count", || {
        for v in [5usize, 10, 20, 30] {
            let cfg = GhostConfig { v, n: v, ..GhostConfig::paper_optimal() };
            let r = engine
                .run(&SimRequest::new(ModelKind::Gcn, "Cora", cfg, OptFlags::ghost_default()))
                .expect("lane-count point simulates");
            println!(
                "  V={v:>2}: {:>9.1} us, {:>6.2} W platform, {:>8.0} GOPS, EPB/GOPS {:.2e}",
                r.metrics.latency_s * 1e6,
                r.platform_w,
                r.metrics.gops(),
                r.metrics.epb_per_gops()
            );
        }
    });

    println!("\n== ablation: FPV mitigation (paper §5 future work) ==");
    time_once("ablation_fpv", || {
        let p = DeviceParams::paper();
        let mr = MicroringDesign::paper();
        for sigma in [0.3, 0.5, 0.8] {
            let model = FpvModel { sigma_nm: sigma, mean_nm: 0.2 };
            let (direct, remap) = eo_only_yield(&p, &mr, &model, 18, 1.0, 500, 7);
            println!(
                "  sigma {sigma:.1} nm: EO-only yield {:.0}% direct -> {:.0}% with remapping",
                direct * 100.0,
                remap * 100.0
            );
        }
    });
}
