//! Bench: the serving event queue at scale, fast path vs retained
//! baseline, and the parallel scenario-sweep executor.
//!
//! Drives ≥100k simulated requests through the discrete-event fleet
//! scheduler (tenant profiles pre-resolved, so the timing isolates the
//! event loop: routing, batching, metric recording), pins the fast
//! loop's report bit-identical to the retained pre-fast-path baseline
//! (`ghost::serve::reference`) while clearing the **≥2× events/sec**
//! floor over it, then times an 8-scenario fleet-shape sweep serial vs
//! parallel — the probes share one engine, so the whole sweep performs
//! exactly one profile and one plan build per tenant (counter-asserted).
//! Results land in `BENCH_serve.json` for the CI perf-trajectory
//! artifact.

use ghost::coordinator::BatchEngine;
use ghost::gnn::models::ModelKind;
use ghost::serve::{
    reference::simulate_fleet_reference, simulate_with_profiles, sweep_with_workers,
    ArrivalProcess, BatchPolicy, RoutePolicy, ServeConfig, TenantMix, TenantProfile,
    TrafficSpec,
};
use ghost::util::bench::{bench, black_box, time_once};
use ghost::util::json::{obj, Json};
use ghost::util::parallel::default_workers;

fn main() {
    let engine = BatchEngine::new();
    let mix = TenantMix::new(vec![
        TenantProfile::new(ModelKind::Gcn, "Cora", 3.0),
        TenantProfile::new(ModelKind::Gat, "Citeseer", 1.0),
        TenantProfile::new(ModelKind::GraphSage, "PubMed", 1.0),
    ])
    .expect("valid mix");

    let mut cfg = ServeConfig::new(
        mix,
        TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 25_000.0 },
    );
    cfg.accelerators = 8;
    cfg.duration_s = 5.0; // ~125k Poisson arrivals at 25k rps
    cfg.batch = BatchPolicy::MaxBatchOrWait { max_batch: 8, max_wait_s: 2e-4 };
    cfg.seed = 7;

    // Resolve the three tenant profiles once — the engine caches them, and
    // the event-loop benches below reuse the resolved slice directly.
    let profiles = time_once("serve_resolve_3_tenant_profiles", || {
        cfg.tenant_requests()
            .iter()
            .map(|req| engine.service_profile(req).expect("tenant simulates"))
            .collect::<Vec<_>>()
    });

    let report = simulate_with_profiles(&cfg, &profiles).expect("serve simulates");
    println!(
        "stream: {} offered / {} completed, throughput {:.0} req/s, \
         p50 {:.3} ms p99 {:.3} ms, fleet util {:.2}",
        report.offered,
        report.completed,
        report.throughput_rps,
        report.latency.p50_s * 1e3,
        report.latency.p99_s * 1e3,
        report.fleet_utilization()
    );
    assert!(
        report.offered >= 100_000,
        "bench must drive >=100k requests through the event queue, got {}",
        report.offered
    );
    assert_eq!(report.offered, report.completed, "fleet must drain");

    // The fast loop restructures the event plumbing, not the simulation:
    // its report must match the retained baseline bit for bit.
    let baseline = simulate_fleet_reference(&cfg, &profiles).expect("reference simulates");
    assert_eq!(report, baseline, "fast event loop diverged from the retained baseline");

    let fast = bench("serve_event_loop_fast_125k_requests", 1, 5, || {
        black_box(simulate_with_profiles(&cfg, &profiles).expect("serve simulates"));
    });
    let reference = bench("serve_event_loop_reference_125k_requests", 1, 5, || {
        black_box(simulate_fleet_reference(&cfg, &profiles).expect("reference simulates"));
    });
    let fast_rps = report.offered as f64 / fast.median.as_secs_f64();
    let reference_rps = report.offered as f64 / reference.median.as_secs_f64();
    let speedup = reference.median.as_secs_f64() / fast.median.as_secs_f64();
    println!(
        "event-loop simulation rate: fast {fast_rps:.0} req/s, \
         reference {reference_rps:.0} req/s ({speedup:.2}x)"
    );
    assert!(
        speedup >= 2.0,
        "serve fast path must clear 2x the baseline events/sec, got {speedup:.2}x \
         (fast {:.1} ms vs reference {:.1} ms median)",
        fast.median.as_secs_f64() * 1e3,
        reference.median.as_secs_f64() * 1e3,
    );

    // Parallel scenario sweep: 8 fleet-shape probes against one shared
    // engine. The first probe to need a tenant builds its plan + profile;
    // everyone else blocks on that cell — so the counters equal the
    // tenant count no matter how many probes or workers ran.
    let sweep_engine = BatchEngine::new();
    let mut scenarios = Vec::new();
    for &accels in &[2usize, 4, 8, 16] {
        for &rps in &[15_000.0, 25_000.0] {
            let mut c = cfg.clone();
            c.accelerators = accels;
            c.duration_s = 1.0; // ~15-25k arrivals per probe
            c.traffic = TrafficSpec::Open { process: ArrivalProcess::Poisson, rps };
            scenarios.push(c);
        }
    }
    let serial_reports = sweep_with_workers(&sweep_engine, &scenarios, 1);
    assert_eq!(
        sweep_engine.profile_builds(),
        3,
        "sweep must build each tenant profile exactly once"
    );
    assert_eq!(
        sweep_engine.plan_builds(),
        3,
        "sweep must build each tenant plan exactly once"
    );
    let workers = default_workers().max(2);
    let parallel_reports = sweep_with_workers(&sweep_engine, &scenarios, workers);
    assert_eq!(
        sweep_engine.profile_builds(),
        3,
        "re-sweeping must be pure cache hits"
    );
    for (s, p) in serial_reports.iter().zip(&parallel_reports) {
        let (s, p) = (s.as_ref().expect("probe runs"), p.as_ref().expect("probe runs"));
        assert_eq!(s, p, "sweep reports must not depend on the worker count");
    }

    let sweep_serial = bench("serve_sweep_8_scenarios_serial", 1, 3, || {
        black_box(sweep_with_workers(&sweep_engine, &scenarios, 1));
    });
    let name = format!("serve_sweep_8_scenarios_{workers}_workers");
    let sweep_parallel = bench(&name, 1, 3, || {
        black_box(sweep_with_workers(&sweep_engine, &scenarios, workers));
    });
    let sweep_speedup =
        sweep_serial.median.as_secs_f64() / sweep_parallel.median.as_secs_f64();
    println!(
        "sweep of {} scenarios: serial {:.1} ms, {workers} workers {:.1} ms ({sweep_speedup:.2}x)",
        scenarios.len(),
        sweep_serial.median.as_secs_f64() * 1e3,
        sweep_parallel.median.as_secs_f64() * 1e3,
    );
    // Scaling is only assertable when the machine has the cores; the
    // determinism and cache-counter asserts above hold everywhere.
    if default_workers() >= 4 {
        assert!(
            sweep_speedup >= 2.0,
            "8 independent probes on >=4 cores must scale >=2x, got {sweep_speedup:.2}x"
        );
    }

    // Routing-policy faceoff on the identical request stream.
    for route in
        [RoutePolicy::RoundRobin, RoutePolicy::JoinShortestQueue, RoutePolicy::GraphAffinity]
    {
        let mut c = cfg.clone();
        c.route = route;
        let r = simulate_with_profiles(&c, &profiles).expect("serve simulates");
        println!(
            "  {:>14}: p50 {:.3} ms | p99 {:.3} ms | util {:.2} | {} weight programs",
            route.name(),
            r.latency.p50_s * 1e3,
            r.latency.p99_s * 1e3,
            r.fleet_utilization(),
            r.total_weight_programs()
        );
    }

    let json = obj(vec![
        ("offered", Json::Num(report.offered as f64)),
        ("fast_median_s", Json::Num(fast.median.as_secs_f64())),
        ("reference_median_s", Json::Num(reference.median.as_secs_f64())),
        ("fast_req_per_s", Json::Num(fast_rps)),
        ("reference_req_per_s", Json::Num(reference_rps)),
        ("speedup", Json::Num(speedup)),
        ("sweep_scenarios", Json::Num(scenarios.len() as f64)),
        ("sweep_serial_median_s", Json::Num(sweep_serial.median.as_secs_f64())),
        ("sweep_parallel_median_s", Json::Num(sweep_parallel.median.as_secs_f64())),
        ("sweep_workers", Json::Num(workers as f64)),
        ("sweep_speedup", Json::Num(sweep_speedup)),
        ("sweep_profile_builds", Json::Num(sweep_engine.profile_builds() as f64)),
        ("sweep_plan_builds", Json::Num(sweep_engine.plan_builds() as f64)),
    ]);
    std::fs::write("BENCH_serve.json", format!("{json}\n")).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
