//! Bench: the serving event queue at scale.
//!
//! Drives ≥100k simulated requests through the discrete-event fleet
//! scheduler (tenant profiles pre-resolved, so the timing isolates the
//! event loop: heap churn, routing, batching, metric recording), then
//! faces the three routing policies off on the same stream.

use ghost::coordinator::BatchEngine;
use ghost::gnn::models::ModelKind;
use ghost::serve::{
    simulate_with_profiles, ArrivalProcess, BatchPolicy, RoutePolicy, ServeConfig, TenantMix,
    TenantProfile, TrafficSpec,
};
use ghost::util::bench::{bench, black_box, time_once};

fn main() {
    let engine = BatchEngine::new();
    let mix = TenantMix::new(vec![
        TenantProfile::new(ModelKind::Gcn, "Cora", 3.0),
        TenantProfile::new(ModelKind::Gat, "Citeseer", 1.0),
        TenantProfile::new(ModelKind::GraphSage, "PubMed", 1.0),
    ])
    .expect("valid mix");

    let mut cfg = ServeConfig::new(
        mix,
        TrafficSpec::Open { process: ArrivalProcess::Poisson, rps: 25_000.0 },
    );
    cfg.accelerators = 8;
    cfg.duration_s = 5.0; // ~125k Poisson arrivals at 25k rps
    cfg.batch = BatchPolicy::MaxBatchOrWait { max_batch: 8, max_wait_s: 2e-4 };
    cfg.seed = 7;

    // Resolve the three tenant profiles once — the engine caches them, and
    // the event-loop bench below reuses the resolved slice directly.
    let profiles = time_once("serve_resolve_3_tenant_profiles", || {
        cfg.tenant_requests()
            .iter()
            .map(|req| engine.service_profile(req).expect("tenant simulates"))
            .collect::<Vec<_>>()
    });

    let report = simulate_with_profiles(&cfg, &profiles).expect("serve simulates");
    println!(
        "stream: {} offered / {} completed, throughput {:.0} req/s, \
         p50 {:.3} ms p99 {:.3} ms, fleet util {:.2}",
        report.offered,
        report.completed,
        report.throughput_rps,
        report.latency.p50_s * 1e3,
        report.latency.p99_s * 1e3,
        report.fleet_utilization()
    );
    assert!(
        report.offered >= 100_000,
        "bench must drive >=100k requests through the event queue, got {}",
        report.offered
    );
    assert_eq!(report.offered, report.completed, "fleet must drain");

    let s = bench("serve_event_loop_125k_requests", 1, 5, || {
        black_box(simulate_with_profiles(&cfg, &profiles).expect("serve simulates"));
    });
    let req_per_s = report.offered as f64 / s.median.as_secs_f64();
    println!("event-loop simulation rate: {req_per_s:.0} requests/s (wall clock)");

    // Routing-policy faceoff on the identical request stream.
    for route in
        [RoutePolicy::RoundRobin, RoutePolicy::JoinShortestQueue, RoutePolicy::GraphAffinity]
    {
        let mut c = cfg.clone();
        c.route = route;
        let r = simulate_with_profiles(&c, &profiles).expect("serve simulates");
        println!(
            "  {:>14}: p50 {:.3} ms | p99 {:.3} ms | util {:.2} | {} weight programs",
            route.name(),
            r.latency.p50_s * 1e3,
            r.latency.p99_s * 1e3,
            r.fleet_utilization(),
            r.total_weight_programs()
        );
    }
}
